"""Structured tracing for the serving path: Chrome trace-event emission.

The serving analogue of the paper's per-cycle pipeline visibility
(sustained II=1 is a *rate* claim — you can only defend it by looking at
the timeline): the scheduler emits one span per request lifecycle phase
(WAITING / PREFILL / DECODE) and one complete event per engine dispatch
(prefill chunk, decode burst with its planned K, tier, slot set and
host-sync wall time), all timestamped by the scheduler's injectable
clock.  Under a virtual clock two identical runs produce byte-identical
trace files — the determinism contract tests pin (DESIGN.md §13).

Output is the Chrome trace-event "JSON array format": one event object
per line inside a top-level array, loadable directly in Perfetto
(https://ui.perfetto.dev) or chrome://tracing.  Events are serialized
with sorted keys and fixed separators so the bytes are a pure function
of the event stream.

Layout convention used by the scheduler (docs/observability.md):

  pid 1 "requests"   — one tid per request id; spans WAITING/PREFILL/
                       DECODE plus first_token / finish instants.
  pid 2 "scheduler"  — tid 0 = prefill lane, tid 1+i = decode lane of
                       the i-th KV tier (sorted); dispatch events plus
                       queue-depth / slots-used counter tracks.

Timestamps are microseconds (Chrome trace convention); the tracer takes
clock values in seconds — whatever clock the scheduler was built with.
"""
from __future__ import annotations

import json
from typing import Dict, List, Optional

# process ids of the two trace rows the scheduler emits (module-level so
# tests and external consumers address the same lanes)
PID_REQUESTS = 1
PID_SCHEDULER = 2


def _us(t_s: float) -> float:
    """Seconds -> microseconds (Chrome trace ts unit)."""
    return round(t_s * 1e6, 3)


class Tracer:
    """Append-only Chrome trace-event buffer.

    All emit methods take clock values in SECONDS (the scheduler clock's
    unit) and convert to the trace's microsecond timebase.  Events are
    kept in emission order; serialization is deterministic (sorted keys,
    compact separators), so identical event streams yield identical
    bytes.
    """

    def __init__(self):
        self.events: List[Dict] = []
        self._named: set = set()

    # -- metadata ----------------------------------------------------------
    def process_name(self, pid: int, name: str) -> None:
        key = ("process", pid)
        if key in self._named:
            return
        self._named.add(key)
        self.events.append({"ph": "M", "name": "process_name", "pid": pid,
                            "tid": 0, "args": {"name": name}})

    def thread_name(self, pid: int, tid: int, name: str) -> None:
        key = ("thread", pid, tid)
        if key in self._named:
            return
        self._named.add(key)
        self.events.append({"ph": "M", "name": "thread_name", "pid": pid,
                            "tid": tid, "args": {"name": name}})

    # -- events ------------------------------------------------------------
    def complete(self, name: str, t0_s: float, t1_s: float, *, pid: int,
                 tid: int, cat: str = "serve",
                 args: Optional[Dict] = None) -> None:
        """One 'X' (complete) event spanning [t0_s, t1_s]."""
        evt = {"ph": "X", "name": name, "cat": cat, "pid": pid, "tid": tid,
               "ts": _us(t0_s), "dur": _us(max(t1_s - t0_s, 0.0))}
        if args:
            evt["args"] = args
        self.events.append(evt)

    def instant(self, name: str, t_s: float, *, pid: int, tid: int,
                cat: str = "serve", args: Optional[Dict] = None) -> None:
        evt = {"ph": "i", "s": "t", "name": name, "cat": cat, "pid": pid,
               "tid": tid, "ts": _us(t_s)}
        if args:
            evt["args"] = args
        self.events.append(evt)

    def counter(self, name: str, t_s: float, values: Dict[str, float], *,
                pid: int = PID_SCHEDULER, tid: int = 0,
                cat: str = "serve") -> None:
        """One 'C' (counter) sample — renders as a stacked track."""
        self.events.append({"ph": "C", "name": name, "cat": cat, "pid": pid,
                            "tid": tid, "ts": _us(t_s),
                            "args": dict(values)})

    # -- serialization -----------------------------------------------------
    def to_json(self) -> str:
        """Chrome trace-event array format, one event per line.  The
        result is both valid RFC JSON (closed array) and line-structured
        (every event is one self-contained JSON object on its own line),
        which is what makes it greppable AND Perfetto-loadable."""
        lines = [json.dumps(e, sort_keys=True, separators=(",", ":"),
                            allow_nan=False)
                 for e in self.events]
        return "[\n" + ",\n".join(lines) + "\n]\n"

    def write(self, path: str) -> str:
        with open(path, "w") as f:
            f.write(self.to_json())
        return path

    def __len__(self) -> int:
        return len(self.events)
