"""Fault-tolerant training demo: train a reduced model, kill it mid-run,
resume from the checkpoint, and verify the loss trajectory is bit-identical
to an uninterrupted run (deterministic data + deterministic optimizer).

Run:  python examples/train_with_failures.py
(the script puts src/ on sys.path itself — no PYTHONPATH needed)
"""
import os
import shutil
import sys
import tempfile

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.launch.train import train

STEPS, ARCH = 24, "granite-8b"


def main():
    d1 = tempfile.mkdtemp(prefix="ckpt_ref_")
    d2 = tempfile.mkdtemp(prefix="ckpt_ft_")
    try:
        print("== reference run (no failures)")
        ref = train(ARCH, smoke=True, steps=STEPS, batch_size=4, seq_len=64,
                    ckpt_dir=d1, ckpt_every=8, log_every=8)

        print("\n== run with a simulated failure at step 13")
        try:
            train(ARCH, smoke=True, steps=STEPS, batch_size=4, seq_len=64,
                  ckpt_dir=d2, ckpt_every=8, log_every=8, fail_at=13)
        except RuntimeError as e:
            print(f"   crashed as planned: {e}")

        print("\n== restart: resumes from the last checkpoint")
        res = train(ARCH, smoke=True, steps=STEPS, batch_size=4, seq_len=64,
                    ckpt_dir=d2, ckpt_every=8, log_every=8)

        drift = abs(ref["final_loss"] - res["final_loss"])
        print(f"\nfinal loss: reference {ref['final_loss']:.6f} vs "
              f"resumed {res['final_loss']:.6f} (|drift| {drift:.2e})")
        assert drift < 1e-5, "resume must be deterministic"
        print("fault-tolerant resume verified.")
    finally:
        shutil.rmtree(d1, ignore_errors=True)
        shutil.rmtree(d2, ignore_errors=True)


if __name__ == "__main__":
    main()
