"""End-to-end driver: continuous-batching mixed-precision LLM serving.

This is the system the paper targets — a quantized checkpoint (projections
and experts in INT4/FP8/FP4 packed codes -> XtraMAC-style MACs; attention
BF16) served as a *stream*: requests join the scheduler at different times,
share one slot-based KV pool, emit tokens as decode batches advance, and
retire as soon as they hit EOS or their token budget — freeing the slot for
the next request.  Uses the reduced qwen3-moe config so it runs on the CPU
container in ~a minute; pass --arch/--full to scale up.

The precision configuration is ONE ``PrecisionPolicy`` (DESIGN.md §12);
``--tiers bf16,int8`` serves BOTH KV tiers concurrently from the same
engine, requests alternating tiers via ``Request.kv_policy`` — runtime
per-request precision switching.

Run:  python examples/serve_mixed_precision.py [--kv-dtype int8]
      python examples/serve_mixed_precision.py --tiers bf16,int8
(the script puts src/ on sys.path itself — no PYTHONPATH needed)
"""
import argparse
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import numpy as np

from repro.configs import get_config
from repro.models.common import QuantMaker
from repro.models import transformer as T
from repro.serve import PrecisionPolicy, Request, SamplingParams, \
    ServeConfig, ServingEngine, Scheduler


def checkpoint_bytes(params):
    """Packed vs dense parameter bytes — the paper's memory win."""
    from repro.models.common import QLinear
    packed_bytes = dense_equiv = 0.0
    for leaf in jax.tree_util.tree_flatten(
            params, is_leaf=lambda x: isinstance(x, QLinear))[0]:
        if isinstance(leaf, QLinear):
            stack = leaf.packed.shape[: leaf.packed.ndim - 2]
            n_stack = int(np.prod(stack)) if stack else 1
            packed_bytes += (leaf.packed.size * leaf.packed.dtype.itemsize
                             + leaf.scales.size * 4)
            dense_equiv += n_stack * leaf.shape[0] * leaf.shape[1] * 2
        else:
            packed_bytes += leaf.size * leaf.dtype.itemsize
            dense_equiv += leaf.size * 2
    return packed_bytes, dense_equiv


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-moe-30b-a3b")
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--n-slots", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=10)
    ap.add_argument("--chunk", type=int, default=8)
    ap.add_argument("--kv-dtype", default="bf16",
                    choices=["bf16", "int8", "fp8"],
                    help="default KV tier (int8/fp8: quantize-on-write)")
    ap.add_argument("--tiers", default=None,
                    help="comma-separated KV tiers served concurrently "
                         "(e.g. bf16,int8): requests alternate tiers via "
                         "Request.kv_policy — per-request runtime "
                         "precision switching (DESIGN.md §12)")
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=not args.full)
    print(f"== {cfg.name}: {cfg.n_layers}L d={cfg.d_model} "
          f"({cfg.family}); schemes proj={cfg.scheme_proj} "
          f"ffn={cfg.scheme_ffn}")
    params = T.build_params(cfg, QuantMaker(jax.random.PRNGKey(0)))

    pb, de = checkpoint_bytes(params)
    print(f"checkpoint bytes: {pb/1e6:.2f} MB packed "
          f"(bf16-dense equivalent {de/1e6:.2f} MB -> {de/pb:.2f}x smaller)")

    # ONE declarative precision contract: weight schemes come from the
    # config (the policy could override them by name pattern), the KV
    # tier is the serving default, requests may switch tiers at runtime
    policy = PrecisionPolicy(kv=args.kv_dtype)
    engine = ServingEngine(cfg, params, ServeConfig(
        max_len=args.prompt_len + args.max_new,
        n_slots=args.n_slots, prefill_chunk=args.chunk,
        policy=policy))
    print(f"precision policy: {engine.policy.to_json()}")
    tiers = [t.strip() for t in args.tiers.split(",")] if args.tiers else None
    sched = Scheduler(engine, tiers=tiers)
    for tier, pool in sorted(sched.pools.items()):
        print(f"KV pool[{tier}]: {pool.n_slots} slots x {pool.max_len} "
              f"positions = {pool.bytes_per_token} "
              f"B/token ({pool.cache_bytes / 1e6:.2f} MB)")

    rng = np.random.default_rng(0)
    prompts = [rng.integers(1, cfg.vocab,
                            (int(rng.integers(args.prompt_len // 2,
                                              args.prompt_len + 1)),))
               .astype(np.int32) for _ in range(args.requests)]

    # Stagger arrivals: half up front, the rest trickle in while the first
    # wave is mid-decode — continuous batching in one screenful.
    def tier_of(i):
        return tiers[i % len(tiers)] if tiers else None

    t0 = time.time()
    pending = list(enumerate(prompts))
    for i, p in pending[: args.requests // 2]:
        sched.submit(Request(prompt=p, kv_policy=tier_of(i),
                             sampling=SamplingParams(
                                 max_new_tokens=args.max_new)))
        print(f"[submit] req {i} (prompt {len(p)} tok"
              + (f", tier {tier_of(i)}" if tiers else "") + ")")
    pending = pending[args.requests // 2:]

    while sched.has_work or pending:
        # trickle arrivals once decode is underway (mid-flight admission);
        # if the scheduler ever drains first, submit immediately instead of
        # spinning (e.g. --requests 1 submits nothing up front)
        if pending and (sched.n_decode_steps >= 2 or not sched.has_work):
            i, p = pending.pop(0)
            sched.submit(Request(prompt=p, kv_policy=tier_of(i),
                                 sampling=SamplingParams(
                                     max_new_tokens=args.max_new)))
            print(f"[submit] req {i} mid-flight (prompt {len(p)} tok"
                  + (f", tier {tier_of(i)}" if tiers else "") + ")")
        events = sched.step()
        for req, slot, tok in events["emitted"]:
            tag = " (first)" if req.n_generated == 1 else ""
            print(f"[token ] req {req.id} slot {slot} -> {tok}{tag}")
        for req in events["finished"]:
            print(f"[retire] req {req.id}: {req.n_generated} tokens "
                  f"({req.finish_reason}); "
                  f"continuation={req.output_tokens}")

    print(f"\nserved {args.requests} requests in {time.time() - t0:.1f}s "
          f"(incl. compile)")
    print("metrics:", sched.metrics.report())


if __name__ == "__main__":
    main()
