"""End-to-end driver: mixed-precision LLM serving with batched requests.

This is the system the paper targets — a quantized checkpoint (projections
and experts in INT4/FP8/FP4 packed codes -> XtraMAC-style MACs; attention
BF16) served with a prefill+decode engine over a KV cache.  Uses the
reduced qwen3-moe config so it runs on the CPU container in ~a minute;
pass --arch/--full to scale up.

Run:  PYTHONPATH=src python examples/serve_mixed_precision.py
"""
import argparse
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.models.common import QuantMaker
from repro.models import transformer as T
from repro.serve import ServeConfig, ServingEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-moe-30b-a3b")
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=24)
    ap.add_argument("--max-new", type=int, default=12)
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=not args.full)
    print(f"== {cfg.name}: {cfg.n_layers}L d={cfg.d_model} "
          f"({cfg.family}); schemes proj={cfg.scheme_proj} "
          f"ffn={cfg.scheme_ffn}")
    params = T.build_params(cfg, QuantMaker(jax.random.PRNGKey(0), plan={}))

    # count packed vs dense parameter bytes — the paper's memory win
    import jax.numpy as jnp
    from repro.models.common import QLinear
    packed_bytes = dense_equiv = 0.0
    for leaf in jax.tree_util.tree_flatten(
            params, is_leaf=lambda x: isinstance(x, QLinear))[0]:
        if isinstance(leaf, QLinear):
            stack = leaf.packed.shape[: leaf.packed.ndim - 2]
            n_stack = int(np.prod(stack)) if stack else 1
            packed_bytes += (leaf.packed.size * leaf.packed.dtype.itemsize
                             + leaf.scales.size * 4)
            dense_equiv += n_stack * leaf.shape[0] * leaf.shape[1] * 2
        else:
            b = leaf.size * leaf.dtype.itemsize
            packed_bytes += b
            dense_equiv += leaf.size * 2
    print(f"checkpoint bytes: {packed_bytes/1e6:.2f} MB packed "
          f"(bf16-dense equivalent {dense_equiv/1e6:.2f} MB -> "
          f"{dense_equiv/packed_bytes:.2f}x smaller)")

    engine = ServingEngine(cfg, params, ServeConfig(
        max_len=args.prompt_len + args.max_new))
    rng = np.random.default_rng(0)
    batch = {"tokens": rng.integers(
        1, cfg.vocab, (args.batch, args.prompt_len)).astype(np.int32)}
    if cfg.family == "vlm":
        batch["patches"] = jnp.full((args.batch, cfg.n_patches, cfg.d_model),
                                    0.02, jnp.bfloat16)
    if cfg.family == "audio":
        batch["frames"] = jnp.full((args.batch, cfg.n_frames, cfg.d_model),
                                   0.02, jnp.bfloat16)

    t0 = time.time()
    out = engine.generate(batch, max_new_tokens=args.max_new)
    dt = time.time() - t0
    print(f"generated [{out['batch']} x {out['generated'].shape[1]}] tokens "
          f"in {dt:.1f}s (incl. compile)")
    print("sampled continuation ids:", out["generated"][0].tolist())


if __name__ == "__main__":
    main()
