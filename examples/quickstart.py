"""Quickstart: the XtraMAC core in 60 seconds.

Shows the paper's three key mechanisms on real numbers:
  1. the unified mantissa-product MAC (bit-exact mixed-precision arithmetic)
  2. lane packing — 2 INT4xBF16 MACs through ONE virtual-DSP multiply
  3. a quantized GEMV through the Pallas kernel vs its jnp oracle

Run:  python examples/quickstart.py
(the script puts src/ on sys.path itself — no PYTHONPATH needed)
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

from repro.core import formats as F
from repro.core.mac import MacConfig, xtramac
from repro.core.packing import (packed_multiply, per_lane_reference,
                                solve_lane_plan, xtramac_packed)

# -- 1. mixed-precision MAC: INT4 x BF16 + BF16 -> BF16 ----------------------
cfg = MacConfig.make("int4", "bf16", "bf16", "bf16")
a = np.array([0b0011])                                # INT4 code for +3
b = F.quantize_f64(F.BF16, np.array([1.5]))           # BF16(1.5)
c = F.quantize_f64(F.BF16, np.array([0.25]))          # BF16(0.25)
p = xtramac(cfg, a, b, c)
print("XtraMAC  3 * 1.5 + 0.25 =", F.BF16.decode_to_f64(p)[0], "(expect 4.75)")

# -- 2. lane packing: P parallel MACs in ONE integer multiply ----------------
plan = solve_lane_plan("int4", "bf16", max_parallelism=4)
print(f"\nlane plan INT4xBF16: P={plan.parallelism}, stride={plan.stride}, "
      f"offsets A={plan.offsets_a} B={plan.offsets_b}, "
      f"DSP util {plan.dsp_utilization:.1%}")
rng = np.random.default_rng(0)
a_bits = rng.integers(0, 16, (5, len(plan.offsets_a)))
b_bits = F.quantize_f64(F.BF16, rng.normal(size=(5, len(plan.offsets_b))))
c_bits = F.quantize_f64(F.BF16, rng.normal(size=(5, plan.parallelism)))
packed = xtramac_packed(cfg, plan, a_bits, b_bits, c_bits)
ref = per_lane_reference(cfg, plan, a_bits, b_bits, c_bits)
assert (packed == ref).all()
print("packed path bit-exact vs per-lane MACs over",
      packed.size, "results  [OK]")

# -- 3. quantized GEMV: packed INT4 weights through the Pallas kernel --------
import jax.numpy as jnp
from repro.kernels.packed_matmul import packed_matmul
from repro.kernels.ref import packed_matmul_ref
from repro.quant.schemes import get_scheme, quantize_weights

w = rng.standard_normal((256, 128)).astype(np.float32) * 0.1
qw = quantize_weights(get_scheme("awq_int4"), w)
x = jnp.asarray(rng.standard_normal((4, 256)), jnp.bfloat16)
out_kernel = packed_matmul(x, qw, bm=4, bn=128, bk=256, interpret=True)
out_ref = packed_matmul_ref(x, qw)
err = float(jnp.max(jnp.abs(out_kernel - out_ref)))
print(f"\npacked GEMV kernel vs oracle: max abs err {err:.2e}  "
      f"(weights: {qw.packed.dtype} {qw.packed.shape}, "
      f"{32 // get_scheme('awq_int4').weight_bits} codes/word)")
assert err < 1e-4
print("quickstart complete.")
