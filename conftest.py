"""Repo-root pytest bootstrap.

Pins the JAX platform to CPU *before* jax initializes its backends, so the
tier-1 suite behaves identically on CPU-only containers and on hosts where
an accelerator happens to be visible (tests are written against CPU
numerics and host-device counts).
"""
import os

os.environ.setdefault("JAX_PLATFORMS", "cpu")
