"""Repo-root pytest bootstrap.

Pins the JAX platform to CPU *before* jax initializes its backends, so the
tier-1 suite behaves identically on CPU-only containers and on hosts where
an accelerator happens to be visible (tests are written against CPU
numerics and host-device counts).
"""
import os

os.environ.setdefault("JAX_PLATFORMS", "cpu")

import pytest  # noqa: E402


@pytest.fixture(autouse=True)
def _reset_kernel_execution_declaration():
    """``kernels.ops`` holds a process-global execution declaration, and
    engines with ``kernel='auto'`` deliberately inherit whatever a driver
    pinned — correct within one serving process, but across tests it means
    whichever test last declared ``kernel='pallas'`` (e.g. a meshless
    pallas baseline) silently flips every later auto-policy engine to the
    pallas path.  Reset to the defaults before each test so kernel-mode
    behaviour is collection-order-independent."""
    from repro.kernels.ops import reset_execution
    reset_execution()
    yield


@pytest.fixture(autouse=True)
def _reset_kernel_site_warnings():
    """Kernel fallback warnings fire once per SITE per process
    (``kernels/ops.py`` site registry) — without a per-test reset, whichever
    test first triggers a fallback consumes that site's warning and any
    later test asserting on it fails depending on collection order.  Clear
    the registry before every test so warn-assertions are order-independent."""
    from repro.kernels.ops import reset_site_warnings
    reset_site_warnings()
    yield
