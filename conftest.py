"""Repo-root pytest bootstrap.

Pins the JAX platform to CPU *before* jax initializes its backends, so the
tier-1 suite behaves identically on CPU-only containers and on hosts where
an accelerator happens to be visible (tests are written against CPU
numerics and host-device counts).
"""
import os

os.environ.setdefault("JAX_PLATFORMS", "cpu")

import pytest  # noqa: E402


@pytest.fixture(autouse=True)
def _rearm_kernel_downgrade_warning():
    """The Pallas-under-partitioning downgrade warns once per PROCESS
    (``kernels/ops.py`` latch) — without a per-test reset, whichever test
    first triggers the downgrade consumes the warning and any later test
    asserting on it fails depending on collection order.  Re-arm the
    latch before every test so warn-assertions are order-independent."""
    from repro.kernels.ops import reset_downgrade_warning
    reset_downgrade_warning()
    yield
