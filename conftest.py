"""Repo-root pytest bootstrap.

Pins the JAX platform to CPU *before* jax initializes its backends, so the
tier-1 suite behaves identically on CPU-only containers and on hosts where
an accelerator happens to be visible (tests are written against CPU
numerics and host-device counts).
"""
import os

os.environ.setdefault("JAX_PLATFORMS", "cpu")

import pytest  # noqa: E402


@pytest.fixture(autouse=True)
def _reset_kernel_site_warnings():
    """Kernel fallback warnings fire once per SITE per process
    (``kernels/ops.py`` site registry) — without a per-test reset, whichever
    test first triggers a fallback consumes that site's warning and any
    later test asserting on it fails depending on collection order.  Clear
    the registry before every test so warn-assertions are order-independent."""
    from repro.kernels.ops import reset_site_warnings
    reset_site_warnings()
    yield
